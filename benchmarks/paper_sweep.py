"""Paper Figs. 2/3/4: SGD vs LARS across a batch-size sweep on the
paper's CNN (§3.1) — test accuracy, train accuracy, generalization error.

Protocol (paper §4): fixed hyperparameters (Table 1) across the sweep,
fixed epoch budget, batch size scaled up until the optimizers separate.
The dataset is the procedural MNIST stand-in (offline container;
DESIGN.md §9), so absolute numbers differ from the paper's MNIST, and the
claims validated are the paper's *shape*:

  C1 both optimizers are comparable at small batch;
  C2 SGD's test accuracy collapses beyond a batch threshold;
  C3 LARS holds materially higher accuracy at large batch;
  C4 generalization error grows much faster for SGD than LARS.

The sweep itself is a :class:`repro.experiments.GridSpec` executed by
the experiment harness (``repro.experiments``): every cell trains
through the large-batch TrainPipeline with in-jit trust-ratio
telemetry, streams a JSONL trajectory into ``--workdir``, and is
resumable mid-grid with ``--resume``. ``--accum-steps`` and
``--precision bf16`` sweep under gradient accumulation / master
weights. ``--accum-bench`` skips the accuracy sweep and instead
measures the execution pipeline itself — a global batch 8x the largest
single-step microbatch, steps/s and compiled peak-memory for f32 vs
bf16 — appending the results to ``BENCH_optimizer.json``.

``--family lm`` runs the token-LM counterpart of the sweep (the paper's
§6 future work): lamb/adamw/lars/sgd cells on a reduced LM config over
the seeded synthetic Markov corpus, eval perplexity as the metric,
optionally under ``--lr-schedule poly_warmup`` (the You et al.
warmup + poly-decay recipe).

Usage: PYTHONPATH=src python -m benchmarks.paper_sweep [--quick]
       PYTHONPATH=src python -m benchmarks.paper_sweep --family lm \
           --optimizers lamb adamw --lr-policy sqrt \
           --lr-schedule poly_warmup
       PYTHONPATH=src python -m benchmarks.paper_sweep --accum-bench
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import lars, schedules
from repro.experiments import GridRunner, GridSpec, aggregate
from repro.experiments.spec import (INIT_LR, LR_DECAY, MOMENTUM,
                                    TRUST_COEF, WEIGHT_DECAY)
from repro.models import build_model
from repro.train import TrainPipeline


# ------------------------------------------------- execution-pipeline bench

def _bench_opt():
    """The sweep's LARS under Table-1 hyperparameters (bench workload)."""
    return lars(schedules.inverse_time_decay(INIT_LR, LR_DECAY),
                momentum=MOMENTUM, weight_decay=WEIGHT_DECAY,
                trust_coefficient=TRUST_COEF)


def accum_bench(*, micro_batch: int = 256, accum_steps: int = 8,
                steps: int = 10, out: str = "BENCH_optimizer.json") -> dict:
    """Benchmark the execution pipeline itself (not accuracy): a global
    batch ``accum_steps``x the largest single-step microbatch, run via
    scan accumulation, for f32 vs bf16 — steps/s and compiled
    peak-memory deltas, merged into ``out`` under
    ``"large_batch_pipeline"`` (the optimizer bench owns the rest of the
    file, so run this after it)."""
    assert accum_steps >= 8, "bench contract: global >= 8x microbatch"
    global_batch = micro_batch * accum_steps
    cfg = get_config("lenet-mnist")
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.random((global_batch, 28, 28, 1)),
                              jnp.float32),
             "y": jnp.asarray(rng.integers(0, 10, global_batch), jnp.int32)}
    rows = []
    for precision in ("f32", "bf16"):
        pipe = TrainPipeline(model, _bench_opt(), cfg,
                             accum_steps=accum_steps, precision=precision)
        state = pipe.init_state(jax.random.key(0))
        peak = None
        try:
            mem = pipe.lower(state, batch).compile().memory_analysis()
            peak = int(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                       + mem.output_size_in_bytes)
        except Exception:
            pass  # backend without memory analysis: report timing only
        state, m = pipe(state, batch)          # compile + warmup
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = pipe(state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / steps
        rows.append({"precision": precision, "micro_batch": micro_batch,
                     "accum_steps": accum_steps,
                     "global_batch": global_batch,
                     "steps_per_s": 1.0 / dt,
                     "examples_per_s": global_batch / dt,
                     "peak_bytes": peak,
                     "loss": float(m["loss"])})
        peak_s = f"{peak / 1e6:8.1f} MB" if peak is not None else "   n/a"
        print(f"{precision:5s} global={global_batch} (micro={micro_batch} "
              f"x accum={accum_steps})  {1.0 / dt:6.2f} steps/s  "
              f"{global_batch / dt:9.0f} ex/s  peak {peak_s}", flush=True)

    by = {r["precision"]: r for r in rows}
    deltas = {"bf16_vs_f32_steps_per_s":
              by["bf16"]["steps_per_s"] / by["f32"]["steps_per_s"] - 1.0}
    if by["f32"]["peak_bytes"] and by["bf16"]["peak_bytes"]:
        deltas["bf16_vs_f32_peak_bytes"] = \
            by["bf16"]["peak_bytes"] / by["f32"]["peak_bytes"] - 1.0
    section = {"backend": jax.default_backend(), "rows": rows,
               "deltas": deltas}
    payload = {}
    if out and os.path.exists(out):
        with open(out) as f:
            payload = json.load(f)
    payload["large_batch_pipeline"] = section
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"merged large_batch_pipeline section into {out}")
    return section


# ----------------------------------------------------------------- sweep

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--family", default="cnn", choices=("cnn", "lm"),
                    help="cnn: the paper's LeNet/MNIST study; lm: the "
                    "token-LM extension (eval perplexity) on a reduced "
                    "LM config")
    ap.add_argument("--arch", default=None,
                    help="model config for --family lm "
                    "(default smollm-135m)")
    ap.add_argument("--seq-len", type=int, default=64,
                    help="LM training sequence length")
    ap.add_argument("--optimizers", nargs="+",
                    default=["sgd", "lars"])
    ap.add_argument("--trust-coef", type=float, default=TRUST_COEF)
    ap.add_argument("--lr-policy", default="none",
                    choices=("none", "linear", "sqrt"))
    ap.add_argument("--lr-schedule", default="inverse_time",
                    choices=("inverse_time", "poly", "poly_warmup"),
                    help="per-cell LR shape; poly_warmup = the You et "
                    "al. large_batch_lr recipe (warmup + poly decay)")
    ap.add_argument("--base-lr", type=float, default=None,
                    help="sgd/lars base LR (default: Table 1's 0.01 for "
                    "cnn, the lm_smoke-tuned 0.3 for lm)")
    ap.add_argument("--n-train", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="write the aggregated report JSON here")
    ap.add_argument("--workdir", default=None,
                    help="harness run directory (default "
                    "runs/<sweep name>)")
    ap.add_argument("--resume", action="store_true",
                    help="continue an interrupted sweep in --workdir")
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="microbatches accumulated per update in each cell")
    ap.add_argument("--precision", default="f32", choices=("f32", "bf16"))
    ap.add_argument("--accum-bench", action="store_true",
                    help="skip the accuracy sweep; benchmark the "
                    "accumulation pipeline (f32 vs bf16) into "
                    "BENCH_optimizer.json")
    args = ap.parse_args()

    if args.accum_bench:
        micro, accum = (64, 8) if args.quick else (256, 8)
        accum_bench(micro_batch=micro, accum_steps=accum,
                    steps=3 if args.quick else 10,
                    out=args.out or "BENCH_optimizer.json")
        return

    lm = args.family == "lm"
    if args.quick:
        n_train, n_test = (512, 64) if lm else (2048, 512)
        batches = (16, 64, 256) if lm else (64, 512, 2048)
        epochs = args.epochs or (1 if lm else 6)
    else:
        n_train, n_test = (8192, 512) if lm else (8192, 2048)
        batches = ((16, 64, 256, 1024) if lm
                   else (32, 128, 512, 1024, 2048, 4096, 8192))
        epochs = args.epochs or (4 if lm else 20)
    if args.n_train:
        n_train = args.n_train

    extra = {}
    if lm:
        # the lm_smoke-tuned per-optimizer bases (see experiments.spec)
        extra = dict(family="lm", arch=args.arch or "smollm-135m",
                     seq_len=args.seq_len, vocab_size=512,
                     model_layers=2, model_d_model=192, base_batch=16,
                     adam_base_lr=0.01,
                     base_lr_overrides=(("lars", 1.0), ("lamb", 0.1)))
    base_lr = args.base_lr if args.base_lr is not None \
        else (0.3 if lm else INIT_LR)
    grid = GridSpec(
        name=("lm_" if lm else "") + (
            "paper_sweep_quick" if args.quick else "paper_sweep"),
        optimizers=tuple(args.optimizers), batches=batches,
        precisions=(args.precision,), accum_steps=(args.accum_steps,),
        lr_policies=(args.lr_policy,),
        lr_schedules=(args.lr_schedule,), epochs=epochs,
        n_train=n_train, n_test=n_test, base_lr=base_lr,
        trust_coef=args.trust_coef, **extra)
    workdir = args.workdir or f"runs/{grid.name}"
    if not args.resume and os.path.exists(
            os.path.join(workdir, "manifest.json")):
        # benchmark semantics: a fresh invocation re-measures (the
        # harness CLI keeps the strict refuse-to-clobber behavior)
        print(f"# discarding previous sweep in {workdir} "
              "(pass --resume to continue it)")
        import shutil
        shutil.rmtree(workdir)
    runner = GridRunner(grid, workdir, log=None)

    print(f"# paper sweep via experiment harness: family={args.family} "
          f"epochs={epochs} n_train={n_train} "
          f"optimizers={args.optimizers} lr_policy={args.lr_policy} "
          f"lr_schedule={args.lr_schedule} trust_coef={args.trust_coef} "
          f"workdir={workdir}")
    if lm:
        print(f"{'opt':6s} {'batch':>6s} {'steps':>6s} {'eval_ppl':>9s} "
              f"{'eval_loss':>10s} {'wall':>6s}")
    else:
        print(f"{'opt':6s} {'batch':>6s} {'steps':>6s} {'train':>7s} "
              f"{'test':>7s} {'gen_err':>8s} {'wall':>6s}")

    def on_row(row: dict) -> None:
        if lm:
            print(f"{row['optimizer']:6s} {row['batch']:6d} "
                  f"{row['steps']:6d} {row['eval_ppl']:9.3f} "
                  f"{row['eval_loss']:10.4f} {row['wall_s']:5.1f}s",
                  flush=True)
        else:
            print(f"{row['optimizer']:6s} {row['batch']:6d} "
                  f"{row['steps']:6d} {row['train_acc']:7.4f} "
                  f"{row['test_acc']:7.4f} {row['gen_error']:8.4f} "
                  f"{row['wall_s']:5.1f}s", flush=True)

    manifest = runner.run(resume=args.resume, on_row=on_row)
    payload = aggregate(grid, manifest)
    if args.out:
        from repro.experiments.record import atomic_write_json
        atomic_write_json(args.out, payload)
        print(f"wrote {args.out}")

    for key, val in payload["claims"].items():
        print(f"claim {key}: {val}")


if __name__ == "__main__":
    main()
