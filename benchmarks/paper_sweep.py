"""Paper Figs. 2/3/4: SGD vs LARS across a batch-size sweep on the
paper's CNN (§3.1) — test accuracy, train accuracy, generalization error.

Protocol (paper §4): fixed hyperparameters (Table 1) across the sweep,
fixed epoch budget, batch size scaled up until the optimizers separate.
The dataset is the procedural MNIST stand-in (offline container;
DESIGN.md §9), so absolute numbers differ from the paper's MNIST, and the
claims validated are the paper's *shape*:

  C1 both optimizers are comparable at small batch;
  C2 SGD's test accuracy collapses beyond a batch threshold;
  C3 LARS holds materially higher accuracy at large batch;
  C4 generalization error grows much faster for SGD than LARS.

Every cell trains through the large-batch TrainPipeline, so the sweep
can take ``--accum-steps`` (global batches beyond one-step memory) and
``--precision bf16`` (f32 master weights). ``--accum-bench`` skips the
accuracy sweep and instead measures the execution pipeline itself — a
global batch 8x the largest single-step microbatch, steps/s and
compiled peak-memory for f32 vs bf16 — appending the results to
``BENCH_optimizer.json``.

Usage: PYTHONPATH=src python -m benchmarks.paper_sweep [--quick]
       PYTHONPATH=src python -m benchmarks.paper_sweep --accum-bench
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import lars, sgd, lamb
from repro.core.scaling import scaled_lr
from repro.data import batch_iterator, synthetic_mnist
from repro.models import build_model
from repro.train import (TrainPipeline, generalization_error,
                         make_eval_step)

# Paper Table 1
INIT_LR = 0.01
LR_DECAY = 1e-4
WEIGHT_DECAY = 1e-4
MOMENTUM = 0.9
TRUST_COEF = 0.001


def make_opt(name: str, base_lr: float, *, trust_coef: float = TRUST_COEF,
             lr_policy: str = "none", base_batch: int = 32, batch: int = 32):
    from repro.core import schedules
    lr0 = scaled_lr(base_lr, base_batch, batch, lr_policy)
    lr = schedules.inverse_time_decay(lr0, LR_DECAY)
    if name == "sgd":
        return sgd(lr, momentum=MOMENTUM, weight_decay=WEIGHT_DECAY)
    if name == "lars":
        return lars(lr, momentum=MOMENTUM, weight_decay=WEIGHT_DECAY,
                    trust_coefficient=trust_coef)
    if name == "lamb":
        return lamb(lr, weight_decay=WEIGHT_DECAY)
    raise ValueError(name)


def run_cell(opt_name: str, batch: int, *, epochs: int, data, seed: int = 0,
             trust_coef: float = TRUST_COEF, lr_policy: str = "none",
             base_lr: float = INIT_LR, accum_steps: int = 1,
             precision: str = "f32") -> dict:
    x_tr, y_tr, x_te, y_te = data
    n = len(x_tr)
    steps = max(1, math.ceil(epochs * n / batch))
    cfg = get_config("lenet-mnist")
    model = build_model(cfg)
    opt = make_opt(opt_name, base_lr, trust_coef=trust_coef,
                   lr_policy=lr_policy, batch=batch)
    eff_batch = min(batch, n)
    if eff_batch % accum_steps:
        raise ValueError(f"batch {eff_batch} not divisible by "
                         f"accum_steps={accum_steps}")
    pipe = TrainPipeline(model, opt, cfg, accum_steps=accum_steps,
                         precision=precision)
    state = pipe.init_state(jax.random.key(seed))
    eval_step = jax.jit(make_eval_step(model, cfg))

    it = batch_iterator(x_tr, y_tr, batch=eff_batch, seed=seed)
    t0 = time.perf_counter()
    for i in range(steps):
        b = next(it)
        state, metrics = pipe(state, {"x": jnp.asarray(b["x"]),
                                      "y": jnp.asarray(b["y"])})
    loss = float(metrics["loss"])

    def acc_of(x, y):
        accs = []
        for i in range(0, len(x), 1024):
            m = eval_step(state.params, {"x": jnp.asarray(x[i:i + 1024]),
                                         "y": jnp.asarray(y[i:i + 1024])})
            accs.append(float(m["accuracy"]) * len(x[i:i + 1024]))
        return sum(accs) / len(x)

    train_acc = acc_of(x_tr, y_tr)
    test_acc = acc_of(x_te, y_te)
    return {"optimizer": opt_name, "batch": batch, "steps": steps,
            "accum_steps": accum_steps, "precision": precision,
            "loss": loss, "train_acc": round(train_acc, 4),
            "test_acc": round(test_acc, 4),
            "gen_error": round(generalization_error(train_acc, test_acc), 4),
            "wall_s": round(time.perf_counter() - t0, 1)}


# ------------------------------------------------- execution-pipeline bench

def accum_bench(*, micro_batch: int = 256, accum_steps: int = 8,
                steps: int = 10, out: str = "BENCH_optimizer.json") -> dict:
    """Benchmark the execution pipeline itself (not accuracy): a global
    batch ``accum_steps``x the largest single-step microbatch, run via
    scan accumulation, for f32 vs bf16 — steps/s and compiled
    peak-memory deltas, merged into ``out`` under
    ``"large_batch_pipeline"`` (the optimizer bench owns the rest of the
    file, so run this after it)."""
    assert accum_steps >= 8, "bench contract: global >= 8x microbatch"
    global_batch = micro_batch * accum_steps
    cfg = get_config("lenet-mnist")
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = {"x": jnp.asarray(rng.random((global_batch, 28, 28, 1)),
                              jnp.float32),
             "y": jnp.asarray(rng.integers(0, 10, global_batch), jnp.int32)}
    rows = []
    for precision in ("f32", "bf16"):
        opt = make_opt("lars", INIT_LR)
        pipe = TrainPipeline(model, opt, cfg, accum_steps=accum_steps,
                             precision=precision)
        state = pipe.init_state(jax.random.key(0))
        peak = None
        try:
            mem = pipe.lower(state, batch).compile().memory_analysis()
            peak = int(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                       + mem.output_size_in_bytes)
        except Exception:
            pass  # backend without memory analysis: report timing only
        state, m = pipe(state, batch)          # compile + warmup
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(steps):
            state, m = pipe(state, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / steps
        rows.append({"precision": precision, "micro_batch": micro_batch,
                     "accum_steps": accum_steps,
                     "global_batch": global_batch,
                     "steps_per_s": 1.0 / dt,
                     "examples_per_s": global_batch / dt,
                     "peak_bytes": peak,
                     "loss": float(m["loss"])})
        peak_s = f"{peak / 1e6:8.1f} MB" if peak is not None else "   n/a"
        print(f"{precision:5s} global={global_batch} (micro={micro_batch} "
              f"x accum={accum_steps})  {1.0 / dt:6.2f} steps/s  "
              f"{global_batch / dt:9.0f} ex/s  peak {peak_s}", flush=True)

    by = {r["precision"]: r for r in rows}
    deltas = {"bf16_vs_f32_steps_per_s":
              by["bf16"]["steps_per_s"] / by["f32"]["steps_per_s"] - 1.0}
    if by["f32"]["peak_bytes"] and by["bf16"]["peak_bytes"]:
        deltas["bf16_vs_f32_peak_bytes"] = \
            by["bf16"]["peak_bytes"] / by["f32"]["peak_bytes"] - 1.0
    section = {"backend": jax.default_backend(), "rows": rows,
               "deltas": deltas}
    payload = {}
    if out and os.path.exists(out):
        with open(out) as f:
            payload = json.load(f)
    payload["large_batch_pipeline"] = section
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"merged large_batch_pipeline section into {out}")
    return section


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--optimizers", nargs="+",
                    default=["sgd", "lars"])
    ap.add_argument("--trust-coef", type=float, default=TRUST_COEF)
    ap.add_argument("--lr-policy", default="none",
                    choices=("none", "linear", "sqrt"))
    ap.add_argument("--base-lr", type=float, default=INIT_LR)
    ap.add_argument("--n-train", type=int, default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="microbatches accumulated per update in each cell")
    ap.add_argument("--precision", default="f32", choices=("f32", "bf16"))
    ap.add_argument("--accum-bench", action="store_true",
                    help="skip the accuracy sweep; benchmark the "
                    "accumulation pipeline (f32 vs bf16) into "
                    "BENCH_optimizer.json")
    args = ap.parse_args()

    if args.accum_bench:
        micro, accum = (64, 8) if args.quick else (256, 8)
        accum_bench(micro_batch=micro, accum_steps=accum,
                    steps=3 if args.quick else 10,
                    out=args.out or "BENCH_optimizer.json")
        return

    if args.quick:
        n_train, n_test = 2048, 512
        batches = [64, 512, 2048]
        epochs = args.epochs or 6
    else:
        n_train, n_test = 8192, 2048
        batches = [32, 128, 512, 1024, 2048, 4096, 8192]
        epochs = args.epochs or 20
    if args.n_train:
        n_train = args.n_train

    data = synthetic_mnist(n_train, n_test, seed=0)
    rows = []
    print(f"# paper sweep: epochs={epochs} n_train={n_train} "
          f"optimizers={args.optimizers} lr_policy={args.lr_policy} "
          f"trust_coef={args.trust_coef}")
    print(f"{'opt':6s} {'batch':>6s} {'steps':>6s} {'train':>7s} "
          f"{'test':>7s} {'gen_err':>8s} {'wall':>6s}")
    for batch in batches:
        for opt_name in args.optimizers:
            row = run_cell(opt_name, batch, epochs=epochs, data=data,
                           trust_coef=args.trust_coef,
                           lr_policy=args.lr_policy, base_lr=args.base_lr,
                           accum_steps=args.accum_steps,
                           precision=args.precision)
            rows.append(row)
            print(f"{row['optimizer']:6s} {row['batch']:6d} "
                  f"{row['steps']:6d} {row['train_acc']:7.4f} "
                  f"{row['test_acc']:7.4f} {row['gen_error']:8.4f} "
                  f"{row['wall_s']:5.1f}s", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.out}")

    # claim checks (only meaningful on the full sweep)
    if not args.quick:
        by = {(r["optimizer"], r["batch"]): r for r in rows}
        largest = max(b for (_, b) in by)
        small = min(b for (_, b) in by)
        if ("lars", largest) in by and ("sgd", largest) in by:
            c3 = by[("lars", largest)]["test_acc"] >= \
                by[("sgd", largest)]["test_acc"]
            print(f"C3 (LARS >= SGD test acc at batch {largest}): {c3}")


if __name__ == "__main__":
    main()
