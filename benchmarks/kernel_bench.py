"""Pallas kernel microbench: fused LARS kernels + flash_decode vs the
pure-jnp oracles, across a shape sweep.

On this CPU container the kernels execute in interpret mode, so the
numbers are CORRECTNESS + op-count evidence, not TPU wall-times (the
jnp oracle column is the meaningful CPU timing; the kernels' value on
real TPU is the fused single-pass HBM traffic, see DESIGN.md §7).

Usage: PYTHONPATH=src python -m benchmarks.kernel_bench [--quick]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def timeit(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    shapes = [(1, 4096), (8, 8192)] if args.quick else \
        [(1, 4096), (8, 8192), (16, 65536)]
    print("# lars_norms / lars_apply (interpret-mode Pallas vs jnp ref)")
    for L, n in shapes:
        key = jax.random.key(L * n)
        w = jax.random.normal(key, (L, n), jnp.float32)
        g = 0.01 * w
        m = jnp.zeros_like(w)
        stacked = L > 1
        t_ref, (wn_r, gn_r) = timeit(
            jax.jit(lambda w, g: ref.lars_norms(w, g, stacked=stacked)), w, g)
        t_k, (wn_k, gn_k) = timeit(
            jax.jit(lambda w, g: ops.lars_norms(w, g, stacked=stacked)), w, g)
        np.testing.assert_allclose(wn_k, wn_r, rtol=1e-5)
        lr = jnp.full((L,) if stacked else (), 0.01)
        t_ar, (w_r, m_r) = timeit(jax.jit(
            lambda w, g, m: ref.lars_apply(w, g, m, local_lr=lr,
                                           momentum=0.9, weight_decay=1e-4)),
            w, g, m)
        t_ak, (w_k, m_k) = timeit(jax.jit(
            lambda w, g, m: ops.lars_apply(w, g, m, local_lr=lr,
                                           momentum=0.9, weight_decay=1e-4)),
            w, g, m)
        np.testing.assert_allclose(w_k, w_r, rtol=1e-5, atol=1e-6)
        print(f"  ({L:2d},{n:6d}) norms ref {t_ref*1e3:7.2f}ms "
              f"pallas(interp) {t_k*1e3:7.2f}ms | apply ref "
              f"{t_ar*1e3:7.2f}ms pallas(interp) {t_ak*1e3:7.2f}ms  OK",
              flush=True)

    print("# flash_decode (interpret) vs blockwise-jnp oracle")
    dshapes = [(2, 8, 2, 64, 512)] if args.quick else \
        [(2, 8, 2, 64, 512), (4, 16, 4, 64, 2048)]
    for B, H, Hkv, D, S in dshapes:
        ks = jax.random.split(jax.random.key(S), 3)
        q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
        lens = jnp.full((B,), S, jnp.int32)
        t_r, o_r = timeit(jax.jit(ref.flash_decode), q, k, v, lens)
        t_k, o_k = timeit(jax.jit(ops.flash_decode), q, k, v, lens)
        np.testing.assert_allclose(o_k, o_r, rtol=2e-4, atol=2e-5)
        print(f"  B{B} H{H} S{S}: ref {t_r*1e3:7.2f}ms "
              f"pallas(interp) {t_k*1e3:7.2f}ms  OK", flush=True)


if __name__ == "__main__":
    main()
