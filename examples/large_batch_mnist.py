"""The paper's experiment, end to end: train the §3.1 CNN on (synthetic)
MNIST at a small and a large batch size with SGD and with LARS, and
report test/train accuracy + generalization error — a scaled-down
version of Figs 2-4 (the full sweep is ``benchmarks/paper_sweep.py``).

Run: PYTHONPATH=src python examples/large_batch_mnist.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.paper_sweep import run_cell  # noqa: E402
from repro.data import synthetic_mnist       # noqa: E402


def main() -> None:
    data = synthetic_mnist(4096, 1024, seed=0)
    print(f"{'opt':6s} {'batch':>6s} {'accum':>6s} {'train':>7s} "
          f"{'test':>7s} {'gen_err':>8s}")
    # the 1024 cell runs its global batch through 4 accumulated
    # microbatches of 256 — the TrainPipeline path that lets the sweep
    # exceed single-step device memory (optimizer update + LARS trust
    # ratio still fire once per global batch).
    for batch, accum in ((64, 1), (1024, 4)):
        for opt in ("sgd", "lars"):
            # the validated Protocol B (EXPERIMENTS.md §Paper-validation)
            row = run_cell(opt, batch, epochs=12, data=data,
                           trust_coef=0.02, lr_policy="linear",
                           accum_steps=accum)
            print(f"{row['optimizer']:6s} {row['batch']:6d} "
                  f"{row['accum_steps']:6d} "
                  f"{row['train_acc']:7.4f} {row['test_acc']:7.4f} "
                  f"{row['gen_error']:8.4f}")


if __name__ == "__main__":
    main()
