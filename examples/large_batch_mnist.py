"""The paper's experiment, end to end: train the §3.1 CNN on (synthetic)
MNIST at a small and a large batch size with SGD and with LARS, and
report test/train accuracy + generalization error — a scaled-down
version of Figs 2-4 (the full study is the experiment harness:
``python -m repro.launch.experiment --grid lars_vs_sgd``).

Run: PYTHONPATH=src python examples/large_batch_mnist.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(
    os.path.dirname(__file__), "..", "src"))

from repro.experiments import GridRunner, GridSpec  # noqa: E402


def main() -> None:
    print(f"{'opt':6s} {'batch':>6s} {'accum':>6s} {'train':>7s} "
          f"{'test':>7s} {'gen_err':>8s}")

    def on_row(row: dict) -> None:
        print(f"{row['optimizer']:6s} {row['batch']:6d} "
              f"{row['accum_steps']:6d} "
              f"{row['train_acc']:7.4f} {row['test_acc']:7.4f} "
              f"{row['gen_error']:8.4f}", flush=True)

    # the validated protocol (EXPERIMENTS_lars_vs_sgd.json): identical
    # tuning budget for both optimizers — linear LR scaling, trust
    # coefficient 0.02. The 1024 cell runs its global batch through 4
    # accumulated microbatches of 256 — the TrainPipeline path that lets
    # the sweep exceed single-step device memory (optimizer update +
    # LARS trust ratio still fire once per global batch).
    with tempfile.TemporaryDirectory() as workdir:
        for batch, accum in ((64, 1), (1024, 4)):
            grid = GridSpec(name=f"example_b{batch}", batches=(batch,),
                            accum_steps=(accum,), lr_policies=("linear",),
                            trust_coef=0.02, epochs=12,
                            n_train=4096, n_test=1024)
            GridRunner(grid, os.path.join(workdir, grid.name),
                       log=None, record_memory=False).run(on_row=on_row)


if __name__ == "__main__":
    main()
