"""Quickstart: build a model from the config registry, train it with the
LARS optimizer, checkpoint, and decode — the whole public API in ~60
lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core import lars, schedules
from repro.data import TokenTaskConfig, token_batches
from repro.models import build_model
from repro.serve import DecodeEngine
from repro.train import create_train_state, make_train_step, train_loop


def main() -> None:
    # 1. config: any of the 10 assigned archs; .reduced() = CPU-scale
    cfg = get_config("qwen3-14b").reduced()
    model = build_model(cfg)

    # 2. the paper's optimizer: layer-wise adaptive rate scaling
    opt = lars(schedules.with_warmup(schedules.constant(0.05), 20),
               momentum=0.9, weight_decay=1e-4, trust_coefficient=0.01)

    state = create_train_state(model, opt, jax.random.key(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"model: {cfg.name} ({cfg.family}), {n:,} params; opt: {opt}")

    # 3. data: synthetic Markov LM task (offline container)
    task = TokenTaskConfig(vocab_size=cfg.vocab_size, seed=0)
    batches = ({"tokens": jnp.asarray(t[:, :64])} for t in
               token_batches(task, batch=16, seq_len=64))

    # 4. train
    step = make_train_step(model, opt, cfg)
    state, hist = train_loop(step, state, batches, num_steps=60,
                             log_every=20)
    assert hist[-1]["loss"] < hist[0]["loss"], "loss should decrease"

    # 5. checkpoint round-trip
    save_checkpoint("/tmp/quickstart_ckpt.npz", state.params)
    params = restore_checkpoint("/tmp/quickstart_ckpt.npz", state.params)

    # 6. serve: batched greedy decode off a prompt
    engine = DecodeEngine(model, params, cfg)
    prompt = {"tokens": jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (4, 8)),
        jnp.int32)}
    out = engine.generate(prompt, max_new_tokens=12)
    print(f"generated tokens:\n{np.asarray(out)}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
