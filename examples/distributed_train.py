"""Distributed training on emulated devices: the SAME sharded train step
the production dry-run lowers, executed for real on 8 host devices
(data=4 x model=2), with LARS trust ratios computed over sharded leaves.

Run: PYTHONPATH=src python examples/distributed_train.py
(Re-execs itself with XLA_FLAGS to expose 8 CPU devices.)
"""

import os
import subprocess
import sys

if os.environ.get("_REPRO_DIST_EXAMPLE") != "1":
    env = dict(os.environ, _REPRO_DIST_EXAMPLE="1",
               XLA_FLAGS=os.environ.get("XLA_FLAGS", "") +
               " --xla_force_host_platform_device_count=8")
    raise SystemExit(subprocess.call([sys.executable] + sys.argv, env=env))

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402
import numpy as np                            # noqa: E402

from repro.configs import get_config          # noqa: E402
from repro.core import lars                   # noqa: E402
from repro.data import TokenTaskConfig, token_batches  # noqa: E402
from repro.distributed import (batch_pspecs, state_pspecs,  # noqa: E402
                               tree_named)
from repro.models import build_model          # noqa: E402
from repro.train import create_train_state, make_train_step  # noqa: E402


def main() -> None:
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen3-14b").reduced()
    model = build_model(cfg)
    opt = lars(0.05, trust_coefficient=0.01)
    # packed=False: per-leaf (tree) opt state, so momentum shards
    # leaf-for-leaf with the FSDP params and the trust-ratio norms run
    # over sharded leaves (XLA inserts the cross-shard reductions).
    state = create_train_state(model, opt, jax.random.key(0), packed=False)

    sspecs = state_pspecs(cfg, jax.eval_shape(lambda: state), mesh)
    bspecs = batch_pspecs(cfg, mesh, batch=8)
    state = jax.device_put(state, tree_named(mesh, sspecs))
    step = jax.jit(make_train_step(model, opt, cfg),
                   in_shardings=(tree_named(mesh, sspecs),
                                 tree_named(mesh, bspecs)),
                   out_shardings=(tree_named(mesh, sspecs), None),
                   donate_argnums=(0,))

    wq = state.params["layers"]["attn"]["wq"]
    print(f"mesh {dict(mesh.shape)}; wq global {wq.shape}, "
          f"per-device shard {wq.addressable_shards[0].data.shape}")

    task = TokenTaskConfig(vocab_size=cfg.vocab_size, branching=2, seed=0)
    with mesh:
        for i, t in enumerate(token_batches(task, batch=8, seq_len=32)):
            batch = {"tokens": jax.device_put(
                jnp.asarray(t[:, :32]), tree_named(mesh, bspecs)["tokens"])}
            state, m = step(state, batch)
            if i % 10 == 0:
                print(f"step {i:3d} loss {float(m['loss']):.4f}")
            if i >= 40:
                break
    print("distributed LARS training on a (4, 2) mesh: OK")


main()
