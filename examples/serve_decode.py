"""Batched serving example: prefill a batch of prompts, then stream
greedy decode steps against the persistent KV/SSM cache — across FOUR
different architecture families (dense GQA, MLA, SSM, hybrid) to show
the one serving API covers them all.

Run: PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import DecodeEngine

ARCHS = ["qwen3-14b", "deepseek-v2-236b", "falcon-mamba-7b", "zamba2-7b"]


def main() -> None:
    rng = np.random.default_rng(0)
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        engine = DecodeEngine(model, params, cfg)
        B, S, new = 4, 16, 24
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
        t0 = time.perf_counter()
        out = engine.generate(prompt, max_new_tokens=new)
        dt = time.perf_counter() - t0
        toks = B * new
        print(f"{arch:22s} ({cfg.family:6s}) prefill {S} + decode {new} "
              f"x batch {B}: {dt:.2f}s ({toks/dt:.0f} tok/s) "
              f"sample={np.asarray(out[0, :8])}")


if __name__ == "__main__":
    main()
