"""Continuous-batching serving example: staggered request arrivals with
heterogeneous prompt/output lengths stream through the slot-paged
ServeEngine — across FOUR architecture families (dense GQA, MLA, SSM,
hybrid) to show one serving API covers them all. Requests join mid-flight
as slots free up; the engine issues ONE donated jitted decode call per
token and reports per-request latency plus aggregate tok/s.

Run: PYTHONPATH=src python examples/serve_decode.py
     PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b \
         --slots 8 --sampler top_k:20:0.7 --set sliding_window=32
"""

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.overrides import apply_overrides
from repro.launch.serve import serve_traffic
from repro.models import build_model
from repro.serve import ServeEngine, parse_sampler

DEFAULT_ARCHS = ["qwen3-14b", "deepseek-v2-236b", "falcon-mamba-7b",
                 "zamba2-7b"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", choices=sorted(ARCHS),
                    help="arch to serve (repeatable; default: one per "
                    "family)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=96)
    ap.add_argument("--sampler", default="greedy",
                    help="greedy | temperature:T | top_k:K[:T] | "
                    "top_p:P[:T]")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--set", action="append", default=[],
                    metavar="FIELD=VALUE", help="config override")
    args = ap.parse_args()

    for arch in args.arch or DEFAULT_ARCHS:
        cfg = apply_overrides(get_config(arch).reduced(), args.set)
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        engine = ServeEngine(model, params, cfg, slots=args.slots,
                             capacity=args.capacity,
                             sampler=parse_sampler(args.sampler),
                             prefill_bucket=8, seed=args.seed)

        # staggered arrivals (every ~2 engine steps), heterogeneous
        # prompt lengths 4..20 and output lengths 4..16
        rng = np.random.default_rng(args.seed)
        traffic = [(2 * i,
                    rng.integers(0, cfg.vocab_size, (int(rng.integers(4, 21)),)),
                    int(rng.integers(4, 17)))
                   for i in range(args.requests)]
        rep = serve_traffic(engine, traffic)

        print(f"{arch:22s} ({cfg.family:6s}) {rep['requests']} reqs, "
              f"{rep['tokens']} tok in {rep['wall_s']:.2f}s "
              f"({rep['tok_per_s']:.0f} tok/s) occ {rep['occupancy']:.2f} "
              f"lat {rep['latency_mean_s']*1e3:.0f}ms "
              f"ttft {rep['ttft_mean_s']*1e3:.0f}ms "
              f"[{rep['decode_steps']} steps, {rep['decode_traces']} trace]")
        for f in rep["finished"][:3]:
            print(f"    req {f.request.rid}: prompt {f.request.prompt_len:2d} "
                  f"-> {f.tokens.size:2d} tok  latency "
                  f"{f.latency*1e3:6.1f} ms  sample={f.tokens[:6]}")


if __name__ == "__main__":
    main()
