"""End-to-end driver: pretrain the FULL smollm-135m config (135M params,
the assigned small-dense arch) on the synthetic token task with LARS for
a few hundred steps on whatever devices are available.

This is the 'real model, real steps' example: full config (30 layers,
d_model 576, vocab 49152), layer-scanned + remat, LARS with sqrt
batch-size LR scaling and warmup.

Run: PYTHONPATH=src python examples/lm_pretrain.py --steps 300 --batch 8
(CPU: ~1-2 s/step at batch 8, seq 256. Add --accum-steps 4 --precision
bf16 to run a 4x global batch through the accumulation pipeline with f32
master weights.)
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import lars, schedules
from repro.core.scaling import scaled_lr
from repro.data import TokenTaskConfig, token_batches
from repro.models import build_model
from repro.train import TrainPipeline, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8,
                    help="GLOBAL batch (split across --accum-steps)")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--precision", default="f32", choices=("f32", "bf16"))
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--base-lr", type=float, default=0.02)
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    model = build_model(cfg)
    lr0 = scaled_lr(args.base_lr, 8, args.batch, "sqrt")
    opt = lars(schedules.with_warmup(
        schedules.cosine_decay(lr0, args.steps), max(args.steps // 20, 1)),
        trust_coefficient=0.01)
    pipe = TrainPipeline(model, opt, cfg, accum_steps=args.accum_steps,
                         precision=args.precision)
    state = pipe.init_state(jax.random.key(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"smollm-135m: {n:,} params (full config); "
          f"global_batch={args.batch} accum={args.accum_steps} "
          f"precision={args.precision} seq={args.seq} lr0={lr0:.4f}")

    task = TokenTaskConfig(vocab_size=4096, seed=0)
    batches = ({"tokens": jnp.asarray(t[:, :args.seq] % cfg.vocab_size)}
               for t in token_batches(task, batch=args.batch,
                                      seq_len=args.seq))
    t0 = time.perf_counter()
    state, hist = train_loop(pipe, state, batches, args.steps,
                             log_every=max(args.steps // 20, 1))
    dt = time.perf_counter() - t0
    print(f"{args.steps} steps in {dt:.1f}s ({dt/args.steps:.2f} s/step); "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
